"""Paged KV-cache subsystem for the continuous batcher.

The dense serving cache gives every slot a contiguous ``max_len`` KV
allocation, so memory — not compute — caps the resident batch, and
replicated admissions need the contiguous-run/defrag machinery of
``slots.py``.  Here the cache is instead ONE shared pool of fixed-size
pages per layer; each slot owns a *page table* ((P,) int32 pool rows, -1
= unmapped) and its KV bytes live wherever the table points:

  * ``PageTable`` — the host-side manager: free list, per-slot page
    rows, admission *reservations* (a slot reserves its worst-case page
    count up front, so demand growth mid-decode can never find the pool
    empty), and alloc/free/evict as pure page-table ops.  Defragmentation
    disappears: pages need no adjacency, so a paged admission that fits
    by count always fits.
  * pure transforms between the dense slot layout and the pooled one
    (``dense_to_pool`` install scatter, ``pool_slot_view`` gather), used
    by the paged ``SlotSurgery``: fingerprints/damage/repair operate on
    the GATHERED dense-layout view, so per-request DMR/TMR works
    unchanged even though replica slots share one pool — replicas hold
    different pool rows but bitwise-identical page *contents*.
  * ``paged_surgery`` / ``make_pre_tick`` — the engine-facing half:
    join installs a dense prefill into freshly-mapped pages, scrub
    releases them, the pre-tick hook demand-maps pages ahead of the
    positions the next transition will write (counted as
    ``page_faults``), zeroing newly-mapped rows so page reuse between
    requests is invisible (clean-on-map: a mapped page's bytes are a
    pure function of the owning request's trajectory).

Layout conventions (the LM decoder state of ``models/lm_cells.py``):
pool leaves are (L, N, ..., ps, d) — layer axis 0, page axis 1, page
lane at ndim-2; the matching dense stacked leaves are (L, B, ..., S, d)
with the slot axis at 1 and S = P * ps.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.redundancy import bit_mismatch_elems

from .slots import SlotSurgery, _bcast, read_slot, slot_fingerprints

Pytree = Any

#: slot-axis sentinel for pool leaves: no slot axis — the leaf is shared
#: by every slot through the page table
POOL = "pool"


# --------------------------------------------------------------------------
# slot-axis inference with pool leaves
# --------------------------------------------------------------------------
def infer_paged_axes(
    make_state: Callable[[int], Pytree], w1: int = 2, w2: int = 3
) -> Pytree:
    """Like ``slots.infer_slot_axes`` but pool leaves (zero
    width-dependent axes) map to the ``POOL`` sentinel instead of
    raising."""
    s1 = jax.eval_shape(lambda: make_state(w1))
    s2 = jax.eval_shape(lambda: make_state(w2))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if not diffs:
            return POOL
        if len(diffs) != 1:
            raise ValueError(
                f"leaf {a.shape}/{b.shape} has {len(diffs)} width-dependent "
                "axes; a paged slot state needs at most one slot axis per "
                "leaf"
            )
        return diffs[0]

    return jax.tree.map(ax, s1, s2)


def mask_slots_paged(
    active: jax.Array, new: Pytree, old: Pytree, axes: Pytree
) -> Pytree:
    """``slots.mask_slots`` for a paged state: pool leaves pass through —
    their writes are already per-slot gated at the scatter (inactive and
    unmapped rows are dropped), and a whole-pool where() would let one
    slot's mask clobber another's pages."""

    def sel(n, o, ax):
        if ax == POOL:
            return n
        return jnp.where(_bcast(active, n.ndim, ax), n, o)

    return jax.tree.map(sel, new, old, axes)


# --------------------------------------------------------------------------
# the host-side page-table manager
# --------------------------------------------------------------------------
class PageTable:
    """Fixed-size KV pages in one shared pool; per-slot page rows.

    Reservation discipline: ``assign(slot, reserve)`` at admission claims
    the slot's worst-case page count against ``available`` (free pages
    minus everyone's outstanding reservations); every page the slot later
    maps (``grow_to``) is drawn from its own reservation.  Admission that
    passes ``can_admit`` therefore guarantees the request can reach its
    full token budget without ever exhausting the pool mid-decode — the
    paged analogue of the dense cache's capacity-by-construction.
    """

    def __init__(self, n_pages: int, page_size: int, pages_per_slot: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError((n_pages, page_size))
        self.n_pages = n_pages
        self.page_size = page_size
        self.pages_per_slot = pages_per_slot
        self._free: list[int] = list(range(n_pages))
        self._rows: dict[int, list[int]] = {}
        self._reserved: dict[int, int] = {}
        #: pages demand-mapped by the pre-tick hook (decode/walk growth,
        #: as opposed to the admission install)
        self.page_faults = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Free pages not spoken for by outstanding reservations."""
        return len(self._free) - sum(self._reserved.values())

    def can_admit(self, n: int) -> bool:
        return n <= self.available

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.page_size)

    def assign(self, slot: int, reserve: int) -> None:
        """Open a slot's (empty) page row and reserve its worst-case page
        count.  ``can_admit(reserve)`` must have been checked."""
        if slot in self._rows:
            raise ValueError(f"slot {slot} already assigned")
        if reserve > self.available:
            raise RuntimeError(
                f"reservation of {reserve} pages exceeds available "
                f"{self.available} (admission must check can_admit)"
            )
        self._rows[slot] = []
        self._reserved[slot] = reserve

    def grow_to(self, slot: int, n_tokens: int, demand: bool = False) -> list[int]:
        """Map pages until the slot covers positions [0, n_tokens); each
        mapped page consumes one unit of the slot's reservation.  Returns
        the newly mapped pool rows (callers zero them: clean-on-map).
        ``demand=True`` counts the growth as page faults."""
        rows = self._rows[slot]
        need = self.pages_for(n_tokens)
        if need > self.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens needs {need} pages > "
                f"pages_per_slot {self.pages_per_slot}"
            )
        new = []
        while len(rows) < need:
            if not self._free:
                raise RuntimeError(
                    "page pool exhausted despite reservations — "
                    "reservation accounting is broken"
                )
            rows.append(self._free.pop(0))
            new.append(rows[-1])
            self._reserved[slot] = max(0, self._reserved[slot] - 1)
        if demand and new:
            self.page_faults += len(new)
        return new

    def rows_of(self, slot: int) -> list[int]:
        return list(self._rows.get(slot, ()))

    def row_array(self, slot: int) -> np.ndarray:
        """(pages_per_slot,) int32 page row of a slot, -1-padded."""
        out = np.full((self.pages_per_slot,), -1, np.int32)
        rows = self._rows.get(slot, ())
        out[: len(rows)] = rows
        return out

    def release(self, slot: int) -> list[int]:
        """Evict: the slot's pages go back to the free list (sorted, for
        deterministic reuse) and its reservation is dropped."""
        rows = self._rows.pop(slot, [])
        self._reserved.pop(slot, None)
        self._free.extend(rows)
        self._free.sort()
        return rows


# --------------------------------------------------------------------------
# pure layout transforms: dense slot leaves <-> page pools
# --------------------------------------------------------------------------
def dense_to_pool(pool: jax.Array, dense: jax.Array, rows: jax.Array) -> jax.Array:
    """Scatter a width-1 dense cache leaf (L, 1, ..., S, d) into the pool
    (L, N, ..., ps, d) at page rows ``rows`` ((P,) int32, -1 = skip).
    Whole pages are written — the dense zero tail past the filled prefix
    lands too, so freshly-mapped install pages come out clean."""
    n, ps = pool.shape[1], pool.shape[-2]
    x = jnp.squeeze(dense, axis=1)  # (L, ..., S, d)
    p = x.shape[-2] // ps
    x = x.reshape(x.shape[:-2] + (p, ps) + x.shape[-1:])
    x = jnp.moveaxis(x, -3, 1)  # (L, P, ..., ps, d)
    safe = jnp.where(rows >= 0, rows, n)  # OOB -> dropped
    return pool.at[:, safe].set(x.astype(pool.dtype))


def pool_slot_view(pool: jax.Array, pages: jax.Array) -> jax.Array:
    """Gather the dense-layout view (L, B, ..., S, d) of every slot from
    the pool through the page tables ``pages`` ((B, P) int32); unmapped
    pages read as zeros.  Bit-identical leaf layout to the dense stacked
    cache — fingerprints, damage accounting, and repair reads all run on
    this view, which is why replica slots holding *different* pool rows
    still fingerprint equal."""
    n = pool.shape[1]
    safe = jnp.clip(pages, 0, n - 1)
    g = pool[:, safe]  # (L, B, P, ..., ps, d)
    mapped = (pages >= 0).reshape((1,) + pages.shape + (1,) * (g.ndim - 3))
    g = jnp.where(mapped, g, 0)
    g = jnp.moveaxis(g, 2, -3)  # (L, B, ..., P, ps, d)
    return g.reshape(g.shape[:-3] + (-1,) + g.shape[-1:])


def paged_view(dec: dict, pages: Optional[jax.Array] = None) -> dict:
    """The dense-layout view of a paged decoder state: pool leaves
    gathered per slot, the raw ``pages`` leaf dropped (replica slots hold
    different rows by construction — comparing them would flag healthy
    replicas).  A strike on the pages leaf still surfaces: the gather
    then reads the wrong (or no) page, and the view diverges."""
    pages = dec["pages"] if pages is None else pages
    view = {k: v for k, v in dec.items() if k not in ("cache", "pages")}
    view["cache"] = {
        "segments": [
            {k: pool_slot_view(v, pages) for k, v in seg.items()}
            for seg in dec["cache"]["segments"]
        ],
        "pos": dec["cache"]["pos"],
    }
    return view


def view_axes_of(axes: Pytree) -> Pytree:
    """Slot axes of ``paged_view``'s output: gathered cache leaves carry
    the slot axis at 1 (dense stacked layout); everything else keeps its
    inferred axis."""
    va = {k: v for k, v in axes.items() if k not in ("cache", "pages")}
    va["cache"] = {
        "segments": [
            jax.tree.map(lambda a: 1, seg) for seg in axes["cache"]["segments"]
        ],
        "pos": axes["cache"]["pos"],
    }
    return va


# --------------------------------------------------------------------------
# paged SlotSurgery
# --------------------------------------------------------------------------
def paged_surgery(
    table: PageTable,
    cell: str,
    axes: Pytree,
    empty: Pytree,
    *,
    reserve_fn: Callable[[Any], int],
) -> SlotSurgery:
    """The engine's slot operations routed through ``table``.

    ``axes`` is the paged state's axis tree (``infer_paged_axes``);
    ``empty`` a width-1 paged slot state (its non-pool leaves scrub
    evicted slots; pool bytes are left in place and cleaned on next map);
    ``reserve_fn(request)`` the worst-case page count of one replica
    slot.  Join receives the DENSE width-1 prefill state and installs it
    into freshly-mapped pages."""
    vaxes = view_axes_of(axes)

    # non-pool state entries may be NESTED (the speculative draft cache
    # is a whole dense cache dict living beside the pool leaves), so the
    # per-slot update/slice run leaf-wise over the subtree
    def _put(dst, src, slot, ax):
        return jax.tree.map(
            lambda d, s, a: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=a
            ),
            dst,
            src,
            ax,
        )

    def _take(src, slot, ax):
        return jax.tree.map(
            lambda s, a: jax.lax.dynamic_slice_in_dim(s, slot, 1, axis=a),
            src,
            ax,
        )

    def _install(st, ss, slot, rows):
        dec = st[cell]
        new = {}
        for k, v in dec.items():
            if k == "cache":
                segs = [
                    {kk: dense_to_pool(pseg[kk], dseg[kk], rows) for kk in pseg}
                    for pseg, dseg in zip(v["segments"], ss["cache"]["segments"])
                ]
                pv = ss["cache"]["pos"].astype(v["pos"].dtype)
                pos = jax.lax.dynamic_update_slice_in_dim(v["pos"], pv, slot, axis=0)
                new[k] = {"segments": segs, "pos": pos}
            elif k == "pages":
                new[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, rows[None].astype(v.dtype), slot, axis=0
                )
            else:
                new[k] = _put(v, ss[k], slot, axes[k])
        return {**st, cell: new}

    def _scrub(st, slot):
        dec = st[cell]
        blank = jnp.full((1, table.pages_per_slot), -1, jnp.int32)
        new = {}
        for k, v in dec.items():
            if k == "cache":
                pv = empty["cache"]["pos"].astype(v["pos"].dtype)
                pos = jax.lax.dynamic_update_slice_in_dim(v["pos"], pv, slot, axis=0)
                new[k] = {"segments": v["segments"], "pos": pos}
            elif k == "pages":
                new[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, blank.astype(v.dtype), slot, axis=0
                )
            else:
                new[k] = _put(v, empty[k], slot, axes[k])
        return {**st, cell: new}

    def _copy_pool(pool, src_rows, dst_rows):
        n = pool.shape[1]
        vals = pool[:, jnp.clip(src_rows, 0, n - 1)]
        dst = jnp.where(dst_rows >= 0, dst_rows, n)  # OOB -> dropped
        return pool.at[:, dst].set(vals)

    def _copy(st, src, dst, src_rows, dst_rows):
        """Replica repair src -> dst: per-slot leaves copied; page
        CONTENTS copied row-by-row (replicas hold the same page count —
        same request, same position); the dst pages leaf is restored from
        the host-authoritative rows, so a strike on the pages leaf itself
        is repaired too."""
        dec = st[cell]
        new = {}
        for k, v in dec.items():
            if k == "cache":
                segs = [
                    {kk: _copy_pool(pseg[kk], src_rows, dst_rows) for kk in pseg}
                    for pseg in v["segments"]
                ]
                pv = jax.lax.dynamic_slice_in_dim(v["pos"], src, 1, axis=0)
                pos = jax.lax.dynamic_update_slice_in_dim(v["pos"], pv, dst, axis=0)
                new[k] = {"segments": segs, "pos": pos}
            elif k == "pages":
                new[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, dst_rows[None].astype(v.dtype), dst, axis=0
                )
            else:
                new[k] = _put(v, _take(v, src, axes[k]), dst, axes[k])
        return {**st, cell: new}

    def _copy_pool_from(pool, other_pool, rows):
        n = pool.shape[1]
        vals = other_pool[:, jnp.clip(rows, 0, n - 1)].astype(pool.dtype)
        dst = jnp.where(rows >= 0, rows, n)
        return pool.at[:, dst].set(vals)

    def _adopt(st, other, slot, rows):
        """DMR §IV adoption: per-slot leaves and the slot's page CONTENTS
        (at the same host rows — a replay never remaps pages) come from
        ``other``; the pages leaf is restored host-authoritatively."""
        dec, odec = st[cell], other[cell]
        new = {}
        for k, v in dec.items():
            if k == "cache":
                segs = [
                    {kk: _copy_pool_from(pseg[kk], oseg[kk], rows) for kk in pseg}
                    for pseg, oseg in zip(v["segments"], odec["cache"]["segments"])
                ]
                opos = odec["cache"]["pos"]
                pv = jax.lax.dynamic_slice_in_dim(opos, slot, 1, axis=0)
                pos = jax.lax.dynamic_update_slice_in_dim(
                    v["pos"], pv.astype(v["pos"].dtype), slot, axis=0
                )
                new[k] = {"segments": segs, "pos": pos}
            elif k == "pages":
                new[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, rows[None].astype(v.dtype), slot, axis=0
                )
            else:
                new[k] = _put(v, _take(odec[k], slot, axes[k]), slot, axes[k])
        return {**st, cell: new}

    jit_install = jax.jit(_install)
    jit_scrub = jax.jit(_scrub)
    jit_copy = jax.jit(_copy)
    jit_adopt = jax.jit(_adopt)
    jit_fps = jax.jit(lambda dec: slot_fingerprints(paged_view(dec), vaxes))

    def _damage_impl(st, a, b):
        return bit_mismatch_elems(
            read_slot(paged_view(st[cell]), a, vaxes),
            read_slot(paged_view(st[cell]), b, vaxes),
        )

    def _damage_vs_impl(st, other, slot):
        return bit_mismatch_elems(
            read_slot(paged_view(st[cell]), slot, vaxes),
            read_slot(paged_view(other[cell]), slot, vaxes),
        )

    jit_damage = jax.jit(_damage_impl)
    jit_damage_vs = jax.jit(_damage_vs_impl)

    def join(st, ss, slot, req=None):
        if req is None:
            raise ValueError(
                "paged join needs the admitting request "
                "(page reservation sizing)"
            )
        table.assign(slot, reserve_fn(req))
        pos0 = int(jax.device_get(ss["cache"]["pos"][0]))
        table.grow_to(slot, pos0)  # install pages: admission, not faults
        rows = jnp.asarray(table.row_array(slot))
        return jit_install(st, ss, jnp.int32(slot), rows)

    def scrub(st, slot):
        table.release(slot)
        return jit_scrub(st, jnp.int32(slot))

    def copy(st, src, dst):
        src_rows = table.row_array(src)
        dst_rows = table.row_array(dst)
        if (src_rows >= 0).sum() != (dst_rows >= 0).sum():
            raise RuntimeError(f"replica slots {src}/{dst} page counts differ")
        sr, dr = jnp.asarray(src_rows), jnp.asarray(dst_rows)
        return jit_copy(st, jnp.int32(src), jnp.int32(dst), sr, dr)

    def adopt(st, other, slot):
        rows = jnp.asarray(table.row_array(slot))
        return jit_adopt(st, other, jnp.int32(slot), rows)

    def _damage_host(st, a, b):
        return float(jax.device_get(jit_damage(st, jnp.int32(a), jnp.int32(b))))

    def _damage_vs_host(st, other, slot):
        return float(jax.device_get(jit_damage_vs(st, other, jnp.int32(slot))))

    return SlotSurgery(
        join=join,
        scrub=scrub,
        copy=copy,
        adopt=adopt,
        fingerprints=jit_fps,
        damage=_damage_host,
        damage_vs=_damage_vs_host,
    )


# --------------------------------------------------------------------------
# pre-tick demand growth
# --------------------------------------------------------------------------
def make_pre_tick(
    table: PageTable, cell: str, batch: int, walk_chunk: int = 1,
    draft_len: int = 0
) -> Callable[[dict], dict]:
    """The engine's pre-tick hook for a paged program: before each
    resident transition, map pages covering every position the tick will
    write (the decode append, up to ``walk_chunk`` prefill-walk tokens,
    or a ``k_eff + 1``-position speculative verify walk), charge them as
    page faults, and ZERO the newly-mapped pool rows (clean-on-map —
    page reuse between requests leaves no stale bytes, so replica
    fingerprints and paged-vs-dense parity hold).

    ``draft_len`` > 0 (speculative engines) makes the hook read the
    per-slot ``spec_k``/``budget`` leaves and apply the SAME effective-
    draft-length clamp as the in-graph walk
    (``models/lm_cells.py:spec_k_eff``) — host and device must agree on
    how far the tick writes, or a verify sub-step would land on an
    unmapped page.  A rejected speculation rolls ``pos`` back but never
    unmaps: the pages stay with the slot (they are inside its
    reservation) and are simply re-written when decode reaches them.

    Runs BEFORE the engine snapshots the tick's input buffer, so a §IV
    replay sees the same page tables the live tick did."""
    # newly-mapped rows per tick is bounded: each active slot crosses at
    # most ceil(max_step/ps)+1 page boundaries
    max_step = max(walk_chunk, draft_len + 1)
    cap = batch * (-(-max_step // table.page_size) + 1)
    max_len = table.pages_per_slot * table.page_size

    def grow(st, rows, grew, clean):
        dec = st[cell]
        new = dict(dec)
        new["pages"] = jnp.where(grew[:, None], rows, dec["pages"])
        # clean rows scatter through an OOB-padded index list: pad
        # entries (row == n_pages) land out of bounds and are dropped
        new["cache"] = {
            "segments": [
                {k: v.at[:, clean].set(0) for k, v in seg.items()}
                for seg in dec["cache"]["segments"]
            ],
            "pos": dec["cache"]["pos"],
        }
        return {**st, cell: new}

    jit_grow = jax.jit(grow)

    def pre_tick(states):
        dec = states[cell]
        leaves = [dec["active"], dec["cache"]["pos"], dec["p_head"], dec["p_len"]]
        if draft_len > 0:
            leaves += [dec["spec_k"], dec["budget"], dec["n_decoded"]]
        host = [np.asarray(x) for x in jax.device_get(leaves)]
        act, pos, p_head, p_len = host[:4]
        rows = np.full((batch, table.pages_per_slot), -1, np.int32)
        grew = np.zeros((batch,), bool)
        clean: list[int] = []
        for s in range(batch):
            if not act[s]:
                continue
            r = int(p_len[s] - p_head[s])
            if r > 0:
                step = min(walk_chunk, r)
            elif draft_len > 0:
                # host mirror of models/lm_cells.py:spec_k_eff — the two
                # clamps must stay in lock-step, or the device verify
                # walk writes a position this hook never mapped
                spec_k, budget, n_dec = host[4], host[5], host[6]
                room = min(
                    int(budget[s]) - int(n_dec[s]) - 2,
                    max_len - 1 - int(pos[s]),
                )
                k_eff = max(0, min(int(spec_k[s]), room, draft_len))
                step = 1 + k_eff
            else:
                step = 1
            new = table.grow_to(s, int(pos[s]) + step, demand=True)
            if new:
                clean.extend(new)
                rows[s] = table.row_array(s)
                grew[s] = True
                if pre_tick.tracer is not None:
                    # one instant per faulting slot: which pool pages
                    # the demand-map just pulled in and for what position
                    pre_tick.tracer.instant(
                        "page_fault",
                        "engine",
                        slot=s,
                        pages=[int(p) for p in new],
                        pos=int(pos[s]) + step,
                    )
        if not grew.any():
            return states
        carr = np.full((cap,), table.n_pages, np.int32)
        carr[: len(clean)] = clean
        rows_d, grew_d, carr_d = map(jnp.asarray, (rows, grew, carr))
        return jit_grow(states, rows_d, grew_d, carr_d)

    #: set by SlotAdapter.attach_tracer when the engine has a tracer —
    #: a function attribute, so the closure stays picklable/simple and
    #: the untraced path is one ``is not None`` check
    pre_tick.tracer = None
    return pre_tick

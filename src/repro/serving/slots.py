"""Slot bookkeeping + pure-array slot surgery for the continuous batcher.

The resident decoder cell has a fixed batch dimension of ``n_slots``; the
engine multiplexes many requests onto it by scattering prompt caches into
free slots between stream ticks and evicting finished ones.  This module
has the two halves of that:

  * ``SlotManager`` — host-side ownership (which request holds which
    slots; per-request *replica* slots for DMR/TMR policies).
  * pure jittable array helpers — ``join_slot`` / ``read_slot`` /
    ``copy_slot`` / ``slot_fingerprints`` / ``mask_slots``, all driven by
    a per-leaf *slot-axis* pytree (``infer_slot_axes``), because the
    decoder state's batch axis is not in the same position on every leaf
    (KV caches stack a layer axis in front; positions are rank-1).

Everything here is model-agnostic: the LM adapter and the toy test
programs use the same helpers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.redundancy import bit_mismatch_elems, fingerprint

Pytree = Any


# --------------------------------------------------------------------------
# slot-axis inference
# --------------------------------------------------------------------------
def infer_slot_axes(
    make_state: Callable[[int], Pytree], w1: int = 2, w2: int = 3
) -> Pytree:
    """Per-leaf slot (batch) axis of a slotted cell state, found
    structurally: evaluate the state's shape at two widths and locate the
    single axis that scales with the width.  Shape-only (``eval_shape``),
    so no arrays are allocated.  Raises if any leaf has zero or several
    width-dependent axes — every leaf of a slotted state must be
    per-slot, otherwise join/leave could not be expressed."""
    s1 = jax.eval_shape(lambda: make_state(w1))
    s2 = jax.eval_shape(lambda: make_state(w2))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"leaf {a.shape}/{b.shape} has {len(diffs)} width-dependent "
                "axes; a slotted cell state needs exactly one slot axis "
                "per leaf"
            )
        return diffs[0]

    return jax.tree.map(ax, s1, s2)


def _bcast(mask: jax.Array, ndim: int, ax: int) -> jax.Array:
    """Reshape a (B,) mask to broadcast against a rank-``ndim`` leaf whose
    slot axis is ``ax``."""
    return mask.reshape((1,) * ax + (-1,) + (1,) * (ndim - ax - 1))


# --------------------------------------------------------------------------
# pure slot surgery (jit these with ``axes`` closed over)
# --------------------------------------------------------------------------
def mask_slots(active: jax.Array, new: Pytree, old: Pytree, axes: Pytree) -> Pytree:
    """Per-slot select: active slots take ``new``, inactive keep ``old``
    bit-for-bit.  The writeback gate of the slot-masked decoder."""
    return jax.tree.map(
        lambda n, o, ax: jnp.where(_bcast(active, n.ndim, ax), n, o), new, old, axes
    )


def join_slot(
    state: Pytree, slot_state: Pytree, slot: jax.Array, axes: Pytree
) -> Pytree:
    """Scatter a width-1 slot state into batch slot ``slot`` (traced index
    is fine — one compile covers every slot)."""

    def put(dst, src, ax):
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=ax
        )

    return jax.tree.map(put, state, slot_state, axes)


def read_slot(state: Pytree, slot: jax.Array, axes: Pytree) -> Pytree:
    """The width-1 view of batch slot ``slot`` (inverse of ``join_slot``)."""
    return jax.tree.map(
        lambda x, ax: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=ax), state, axes
    )


def copy_slot(state: Pytree, src: jax.Array, dst: jax.Array, axes: Pytree) -> Pytree:
    """Copy slot ``src`` over slot ``dst`` — TMR repair: re-synchronize a
    minority replica slot from a majority one (exact, bitwise)."""
    return join_slot(state, read_slot(state, src, axes), dst, axes)


def slot_fingerprints(state: Pytree, axes: Pytree) -> jax.Array:
    """(B, 4) uint32: the 128-bit state fingerprint of every slot's view
    of the state.  Replica slots of one request are bitwise-equal by
    construction, so equal fingerprints <=> healthy; the engine compares
    these between ticks to detect (DMR) and localize (TMR) strikes at
    request granularity, at O(B * 16 bytes) host traffic."""
    moved = jax.tree.map(lambda x, ax: jnp.moveaxis(x, ax, 0), state, axes)
    return jax.vmap(fingerprint)(moved)


# --------------------------------------------------------------------------
# the surgery protocol: how the engine cuts state in and out of slots
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SlotSurgery:
    """The engine's slot-state operations, bundled so a state layout can
    swap in its own implementations (``serving/paging.py`` routes these
    through a page table; ``default_surgery`` is the dense whole-leaf
    layout the helpers above implement directly).

    All slot arguments are host ints; ``damage``/``damage_vs`` return
    host floats (mismatched elements, temporal-lockstep units).

      join(states, slot_state, slot, req=None)  scatter a width-1 state in
      scrub(states, slot)                       evict: slot back to empty
      copy(states, src, dst)                    bitwise slot copy (repair)
      adopt(states, other, slot)                take ``other``'s slot view
      fingerprints(cell_state) -> (B, 4) u32    per-slot 128-bit fps
      damage(states, a, b) -> float             mismatch between two slots
      damage_vs(states, other, slot) -> float   mismatch vs another state
    """

    join: Callable[..., dict]
    scrub: Callable[[dict, int], dict]
    copy: Callable[[dict, int, int], dict]
    adopt: Callable[[dict, dict, int], dict]
    fingerprints: Callable[[Pytree], jax.Array]
    damage: Callable[[dict, int, int], float]
    damage_vs: Callable[[dict, dict, int], float]


def default_surgery(
    cell: str, axes: Pytree, make_empty: Callable[[], Pytree]
) -> SlotSurgery:
    """Dense-layout surgery: every leaf is whole-per-slot, so join/copy/
    adopt are the pure helpers above, jitted once with ``axes`` closed
    over (traced slot indices — one compile covers every slot)."""
    _join = jax.jit(
        lambda st, ss, slot: {**st, cell: join_slot(st[cell], ss, slot, axes)}
    )
    _copy = jax.jit(
        lambda st, src, dst: {**st, cell: copy_slot(st[cell], src, dst, axes)}
    )

    def _adopt_impl(st, other, slot):
        taken = read_slot(other[cell], slot, axes)
        return {**st, cell: join_slot(st[cell], taken, slot, axes)}

    _adopt = jax.jit(_adopt_impl)
    _fps = jax.jit(lambda dec: slot_fingerprints(dec, axes))

    # real damage accounting: mismatched ELEMENTS between two replica
    # slots (same semantics as temporal lockstep's bitwise compare), not
    # fingerprint words
    def _damage_impl(st, a, b):
        return bit_mismatch_elems(
            read_slot(st[cell], a, axes), read_slot(st[cell], b, axes)
        )

    def _damage_vs_impl(st, other, slot):
        return bit_mismatch_elems(
            read_slot(st[cell], slot, axes), read_slot(other[cell], slot, axes)
        )

    _damage = jax.jit(_damage_impl)
    _damage_vs = jax.jit(_damage_vs_impl)

    def _damage_host(st, a, b):
        return float(jax.device_get(_damage(st, jnp.int32(a), jnp.int32(b))))

    def _damage_vs_host(st, other, slot):
        return float(jax.device_get(_damage_vs(st, other, jnp.int32(slot))))

    empty = make_empty()
    return SlotSurgery(
        join=lambda st, ss, slot, req=None: _join(st, ss, jnp.int32(slot)),
        scrub=lambda st, slot: _join(st, empty, jnp.int32(slot)),
        copy=lambda st, src, dst: _copy(st, jnp.int32(src), jnp.int32(dst)),
        adopt=lambda st, other, slot: _adopt(st, other, jnp.int32(slot)),
        fingerprints=_fps,
        damage=_damage_host,
        damage_vs=_damage_vs_host,
    )


# --------------------------------------------------------------------------
# host-side ownership
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SlotManager:
    """Ownership of the resident batch's slots.

    A request occupies ``policy.level`` slots (1 = none, 2 = DMR, 3 =
    TMR): replication maps onto *extra batch rows* of the decoder — the
    same observation that makes cell replication "mechanically identical
    to data parallelism" (core/redundancy.py), applied per request, so
    unprotected requests pay nothing for their neighbors' protection.

    Replica slots are allocated CONTIGUOUS (``alloc(..., contiguous=
    True)``) so a replicated request occupies one aligned run of batch
    rows.  Churn fragments the free list; rather than rejecting a
    replicated admission that fits by count but not by adjacency,
    ``defrag_plan``/``relocate`` let the engine compact: a running
    request's slot is moved with the existing ``copy_slot`` + scrub
    machinery (bitwise-transparent to its owner — the slot-position
    invariance tested in tests/test_serving.py), so fragmentation never
    blocks an admission the batch has capacity for.

    SPATIAL placement (``pods > 1``): the global slot space is the
    concatenation of ``pods`` per-pod row blocks — pod ``p`` owns global
    slots ``[p*spp, (p+1)*spp)`` where ``spp = n_slots // pods`` (the
    mesh shards the decoder's slot axis over the pod axis in exactly
    this blocked layout).  ``alloc(..., spatial=True)`` reserves the
    SAME column on pods ``0..n-1`` — one replica slot per pod, so a
    hardware strike on one pod hits exactly one replica — and there is
    no adjacency requirement at all: spatial admissions never
    defragment, and spatial tenants are pinned (``defrag_plan`` never
    relocates them, which would tear a replica off its pod).  Temporal
    runs and defrag windows are confined to a single pod's block, and
    unreplicated requests fill from the HIGHEST pod down so low-pod
    columns stay clear for spatial groups (level-1 traffic uses pods as
    plain data parallelism).
    """

    n_slots: int
    pods: int = 1

    def __post_init__(self):
        if self.pods < 1 or self.n_slots % self.pods:
            raise ValueError(
                f"n_slots={self.n_slots} must be a positive multiple of "
                f"pods={self.pods} (the mesh splits the slot axis evenly)"
            )
        self.per_pod = self.n_slots // self.pods
        self._free: list[int] = list(range(self.n_slots))
        self._slots_of: dict[str, list[int]] = {}
        self._owner: dict[int, str] = {}
        self._pinned: set[int] = set()  # spatial tenants: never relocated

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def active(self) -> int:
        return self.n_slots - len(self._free)

    def slots_of(self, rid: str) -> list[int]:
        return list(self._slots_of.get(rid, ()))

    def owner(self, slot: int) -> Optional[str]:
        return self._owner.get(slot)

    def alloc(
        self,
        rid: str,
        n: int,
        contiguous: bool = False,
        spatial: bool = False,
    ) -> Optional[list[int]]:
        """n free slots for request ``rid``; None if the batch can't fit
        it right now.  ``contiguous=True`` (replicated requests) requires
        one adjacent run of n slots — run ``defrag_plan``/``relocate``
        first if ``find_run`` comes up empty.  ``spatial=True`` instead
        reserves one slot PER POD at a shared column (``find_column``) —
        no adjacency, no defrag; the returned list is ordered by pod, so
        replica index i lives on pod i."""
        if rid in self._slots_of:
            raise ValueError(f"request {rid!r} already holds slots")
        if n > len(self._free):
            return None
        if spatial and n > 1:
            if n > self.pods:
                return None
            col = self.find_column(n)
            if col is None:
                return None
            got = [p * self.per_pod + col for p in range(n)]
            for s in got:
                self._free.remove(s)
            self._pinned.update(got)
        elif contiguous and n > 1:
            start = self.find_run(n)
            if start is None:
                return None
            got = list(range(start, start + n))
            for s in got:
                self._free.remove(s)
        elif self.pods > 1:
            # unreplicated / unconstrained: fill from the highest pod
            # down, keeping low-pod columns open for spatial groups
            got = [self._free.pop() for _ in range(n)]
        else:
            got = [self._free.pop(0) for _ in range(n)]
        self._slots_of[rid] = got
        for s in got:
            self._owner[s] = rid
        return list(got)  # caller-owned copy: relocate() mutates ours

    def find_run(self, n: int) -> Optional[int]:
        """Start index of the leftmost run of ``n`` adjacent free slots
        (confined to one pod's block when ``pods > 1`` — a run crossing
        a pod boundary is not adjacent on any device)."""
        free = set(self._free)
        for start in range(self.n_slots - n + 1):
            if start // self.per_pod != (start + n - 1) // self.per_pod:
                continue
            if all(start + i in free for i in range(n)):
                return start
        return None

    def find_column(self, n: int) -> Optional[int]:
        """Lowest column ``c`` whose slot is free on pods ``0..n-1`` —
        the spatial-placement allocation unit (one replica per pod at a
        shared column index)."""
        free = set(self._free)
        for c in range(self.per_pod):
            if all(p * self.per_pod + c in free for p in range(n)):
                return c
        return None

    def defrag_plan(self, n: int) -> Optional[list[tuple[int, int]]]:
        """Relocations ``[(src, dst), ...]`` that open an n-slot adjacent
        free run: pick the window holding the fewest REPLICA slots, then
        the fewest tenants overall (single-slot tenants are the preferred
        eviction victims — moving a replicated tenant's slot would
        scatter the adjacent run it was just given), and evacuate them
        into free slots outside the window.  None if total free capacity
        < n; [] if a run already exists.  Always satisfiable when ``free
        >= n``: a window of n slots has at most ``n - free_inside``
        tenants and there are exactly ``free_total - free_inside >=
        n - free_inside`` free slots outside it.  (When every window
        overlaps a replicated tenant, one is evacuated and loses
        adjacency — correctness is unaffected, the run layout degrades.)

        Windows never cross a pod boundary (a cross-pod run is not
        adjacent on any device) and never overlap a PINNED (spatial)
        tenant — relocating one would tear a replica off its pod — so
        with spatial tenants resident the plan can come back None even
        when free capacity exists; the admission then simply waits.
        """
        if n > len(self._free):
            return None
        free = set(self._free)

        def cost(start):
            occ = [s for s in range(start, start + n) if s not in free]
            repl = sum(1 for s in occ if len(self._slots_of[self._owner[s]]) > 1)
            return (repl, len(occ)), occ

        best_cost, best_start, best_occ = None, None, None
        for start in range(self.n_slots - n + 1):
            if start // self.per_pod != (start + n - 1) // self.per_pod:
                continue
            if any(s in self._pinned for s in range(start, start + n)):
                continue
            c, occ = cost(start)
            if best_cost is None or c < best_cost:
                best_cost, best_start, best_occ = c, start, occ
        if best_start is None:
            return None
        dsts = [
            s
            for s in sorted(free)
            if (s < best_start or s >= best_start + n) and s not in self._pinned
        ]
        return list(zip(best_occ, dsts))

    def relocate(self, src: int, dst: int) -> str:
        """Move the tenant of slot ``src`` to free slot ``dst`` (ownership
        only — the engine performs the matching state copy + scrub).
        Returns the owning request id."""
        rid = self._owner.pop(src)
        self._free.remove(dst)
        self._free.append(src)
        self._free.sort()
        self._owner[dst] = rid
        sl = self._slots_of[rid]
        sl[sl.index(src)] = dst
        return rid

    def release(self, rid: str) -> list[int]:
        got = self._slots_of.pop(rid, [])
        for s in got:
            del self._owner[s]
            self._pinned.discard(s)
            self._free.append(s)
        self._free.sort()  # deterministic reuse order
        return got

"""Slot bookkeeping + pure-array slot surgery for the continuous batcher.

The resident decoder cell has a fixed batch dimension of ``n_slots``; the
engine multiplexes many requests onto it by scattering prompt caches into
free slots between stream ticks and evicting finished ones.  This module
has the two halves of that:

  * ``SlotManager`` — host-side ownership (which request holds which
    slots; per-request *replica* slots for DMR/TMR policies).
  * pure jittable array helpers — ``join_slot`` / ``read_slot`` /
    ``copy_slot`` / ``slot_fingerprints`` / ``mask_slots``, all driven by
    a per-leaf *slot-axis* pytree (``infer_slot_axes``), because the
    decoder state's batch axis is not in the same position on every leaf
    (KV caches stack a layer axis in front; positions are rank-1).

Everything here is model-agnostic: the LM adapter and the toy test
programs use the same helpers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.redundancy import fingerprint

Pytree = Any


# --------------------------------------------------------------------------
# slot-axis inference
# --------------------------------------------------------------------------
def infer_slot_axes(make_state: Callable[[int], Pytree],
                    w1: int = 2, w2: int = 3) -> Pytree:
    """Per-leaf slot (batch) axis of a slotted cell state, found
    structurally: evaluate the state's shape at two widths and locate the
    single axis that scales with the width.  Shape-only (``eval_shape``),
    so no arrays are allocated.  Raises if any leaf has zero or several
    width-dependent axes — every leaf of a slotted state must be
    per-slot, otherwise join/leave could not be expressed."""
    s1 = jax.eval_shape(lambda: make_state(w1))
    s2 = jax.eval_shape(lambda: make_state(w2))

    def ax(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"leaf {a.shape}/{b.shape} has {len(diffs)} width-dependent "
                "axes; a slotted cell state needs exactly one slot axis "
                "per leaf")
        return diffs[0]

    return jax.tree.map(ax, s1, s2)


def _bcast(mask: jax.Array, ndim: int, ax: int) -> jax.Array:
    """Reshape a (B,) mask to broadcast against a rank-``ndim`` leaf whose
    slot axis is ``ax``."""
    return mask.reshape((1,) * ax + (-1,) + (1,) * (ndim - ax - 1))


# --------------------------------------------------------------------------
# pure slot surgery (jit these with ``axes`` closed over)
# --------------------------------------------------------------------------
def mask_slots(active: jax.Array, new: Pytree, old: Pytree,
               axes: Pytree) -> Pytree:
    """Per-slot select: active slots take ``new``, inactive keep ``old``
    bit-for-bit.  The writeback gate of the slot-masked decoder."""
    return jax.tree.map(
        lambda n, o, ax: jnp.where(_bcast(active, n.ndim, ax), n, o),
        new, old, axes)


def join_slot(state: Pytree, slot_state: Pytree, slot: jax.Array,
              axes: Pytree) -> Pytree:
    """Scatter a width-1 slot state into batch slot ``slot`` (traced index
    is fine — one compile covers every slot)."""
    return jax.tree.map(
        lambda dst, src, ax: jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=ax),
        state, slot_state, axes)


def read_slot(state: Pytree, slot: jax.Array, axes: Pytree) -> Pytree:
    """The width-1 view of batch slot ``slot`` (inverse of ``join_slot``)."""
    return jax.tree.map(
        lambda x, ax: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=ax),
        state, axes)


def copy_slot(state: Pytree, src: jax.Array, dst: jax.Array,
              axes: Pytree) -> Pytree:
    """Copy slot ``src`` over slot ``dst`` — TMR repair: re-synchronize a
    minority replica slot from a majority one (exact, bitwise)."""
    return join_slot(state, read_slot(state, src, axes), dst, axes)


def slot_fingerprints(state: Pytree, axes: Pytree) -> jax.Array:
    """(B, 4) uint32: the 128-bit state fingerprint of every slot's view
    of the state.  Replica slots of one request are bitwise-equal by
    construction, so equal fingerprints <=> healthy; the engine compares
    these between ticks to detect (DMR) and localize (TMR) strikes at
    request granularity, at O(B * 16 bytes) host traffic."""
    moved = jax.tree.map(lambda x, ax: jnp.moveaxis(x, ax, 0), state, axes)
    return jax.vmap(fingerprint)(moved)


# --------------------------------------------------------------------------
# host-side ownership
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SlotManager:
    """Ownership of the resident batch's slots.

    A request occupies ``policy.level`` slots (1 = none, 2 = DMR, 3 =
    TMR): replication maps onto *extra batch rows* of the decoder — the
    same observation that makes cell replication "mechanically identical
    to data parallelism" (core/redundancy.py), applied per request, so
    unprotected requests pay nothing for their neighbors' protection.
    """

    n_slots: int

    def __post_init__(self):
        self._free: list[int] = list(range(self.n_slots))
        self._slots_of: dict[str, list[int]] = {}
        self._owner: dict[int, str] = {}

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def active(self) -> int:
        return self.n_slots - len(self._free)

    def slots_of(self, rid: str) -> list[int]:
        return list(self._slots_of.get(rid, ()))

    def owner(self, slot: int) -> Optional[str]:
        return self._owner.get(slot)

    def alloc(self, rid: str, n: int) -> Optional[list[int]]:
        """n contiguous-in-ownership (not necessarily adjacent) free slots
        for request ``rid``; None if the batch can't fit it right now."""
        if rid in self._slots_of:
            raise ValueError(f"request {rid!r} already holds slots")
        if n > len(self._free):
            return None
        got = [self._free.pop(0) for _ in range(n)]
        self._slots_of[rid] = got
        for s in got:
            self._owner[s] = rid
        return got

    def release(self, rid: str) -> list[int]:
        got = self._slots_of.pop(rid, [])
        for s in got:
            del self._owner[s]
            self._free.append(s)
        self._free.sort()  # deterministic reuse order
        return got

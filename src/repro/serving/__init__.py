"""Continuous-batching serving over the MISO runtime (``miso.serve``).

Layers:
  * ``request``  — Request + bounded admission queue (deadlines,
    cancellation, back-pressure).
  * ``slots``    — slot ownership + pure-array slot surgery (join/leave/
    copy/fingerprint) over the resident decoder batch.
  * ``engine``   — the ServingEngine: Executor.stream + swap hook, per-
    request DMR/TMR on replica slots, per-request fault attribution,
    tokens/s + TTFT SLO metrics.
  * ``paging``   — the paged KV cache: PageTable (fixed-size KV pages in
    one shared pool, per-slot page lists) + the page-table-routed
    SlotSurgery; ``ServeConfig(paged=True)`` turns it on.
  * ``lm``       — the LM adapter (slot-masked decoder cell of
    models/lm_cells.py); imported lazily so toy/generic engines don't
    pull in the transformer stack.
"""

from .engine import (  # noqa: F401
    EngineConfig,
    EngineParts,
    RequestRecord,
    ServingEngine,
    SlotAdapter,
)
from .paging import (  # noqa: F401
    PageTable,
    infer_paged_axes,
    mask_slots_paged,
    paged_surgery,
    paged_view,
    pool_slot_view,
)
from .request import (  # noqa: F401
    CANCELLED,
    DONE,
    EXPIRED,
    QUEUED,
    REJECTED,
    RUNNING,
    Request,
    RequestQueue,
)
from .slots import (  # noqa: F401
    SlotManager,
    SlotSurgery,
    copy_slot,
    default_surgery,
    infer_slot_axes,
    join_slot,
    mask_slots,
    read_slot,
    slot_fingerprints,
)

__all__ = [
    "CANCELLED",
    "DONE",
    "EXPIRED",
    "EngineConfig",
    "EngineParts",
    "PageTable",
    "QUEUED",
    "REJECTED",
    "RUNNING",
    "Request",
    "RequestQueue",
    "RequestRecord",
    "ServingEngine",
    "SlotAdapter",
    "SlotManager",
    "SlotSurgery",
    "copy_slot",
    "default_surgery",
    "infer_paged_axes",
    "infer_slot_axes",
    "join_slot",
    "lm_engine_parts",
    "mask_slots",
    "mask_slots_paged",
    "paged_surgery",
    "paged_view",
    "pool_slot_view",
    "read_slot",
    "slot_fingerprints",
]


def __getattr__(name):
    if name == "lm_engine_parts":
        from .lm import lm_engine_parts

        return lm_engine_parts
    raise AttributeError(name)

"""Spatial serving support: cross-pod strike detection and sharding pins.

With ``placement="spatial"`` a DMR/TMR request's replica slots sit at the
same slot COLUMN on different mesh pods (pod ``p`` owns global slots
``[p*spp, (p+1)*spp)``), so replica ``r`` of the group anchored at column
``c`` is global slot ``r*spp + c`` — the replica index IS the pod index.
Detection then stops being a host-side fingerprint walk over every slot
and becomes one O(1)-wire collective per tick (``distributed/
collectives.py``):

  DMR  — each pod fingerprints its local slots (128 bits each) and the
         member pods exchange them through ``psum_delta``: the delta is
         nonzero exactly where the two members disagree, 16 bytes per
         active column on the wire, no all_gather.
  TMR  — one ``all_gather`` of the (spp, 4) fingerprint block; every pod
         then runs the same majority pick locally, so the struck-pod
         verdict is replicated for free.

Both variants compute the *identical* per-slot fingerprints the temporal
engine compares on the host (``slots.slot_fingerprints``), which is what
makes spatial and temporal detection agree event-for-event — the parity
gate in tests/test_serving_spatial.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed.collectives import psum_delta

from .slots import SlotSurgery, slot_fingerprints


def make_detect(mesh, axes, *, pod_axis: str = "pod", tmr: bool):
    """-> jitted ``detect(dec_state, lvl) -> (events, struck)``.

    ``lvl`` is a replicated (spp,) int32 array: the redundancy level of
    the spatial group anchored at each column (0 = no group there this
    tick).  ``events[c]`` is 1 where the group at column ``c`` diverged;
    ``struck[c]`` is the struck pod for a TMR majority verdict, -1 when
    healthy or not localizable (DMR), -2 on TMR triple divergence (all
    three disagree — fall back to replay, same as DMR).  Outputs are
    computed identically on every pod, so they come back replicated.

    Two statically-compiled variants: the DMR-only one (``tmr=False``)
    never gathers; the ``tmr=True`` one serves mixed DMR+TMR ticks from
    the one all_gather.  The engine picks per tick.
    """

    def leaf_spec(ax):
        return P(*((None,) * ax + (pod_axis,)))

    dec_specs = jax.tree.map(leaf_spec, axes)

    def local(dec, lvl):
        h = slot_fingerprints(dec, axes)  # (spp, 4) u32, pod-local slots
        if tmr:
            hs = jax.lax.all_gather(h, pod_axis)  # (n_pods, spp, 4)
            eq01 = jnp.all(hs[0] == hs[1], axis=-1)
            eq02 = jnp.all(hs[0] == hs[2], axis=-1)
            eq12 = jnp.all(hs[1] == hs[2], axis=-1)
            healthy3 = eq01 & eq02
            # first agreeing pair wins, same precedence as the temporal
            # engine's [(0,1), (0,2), (1,2)] walk; no pair -> -2 (replay)
            struck3 = jnp.where(eq12, jnp.int32(0), jnp.int32(-2))
            struck3 = jnp.where(eq02, jnp.int32(1), struck3)
            struck3 = jnp.where(eq01, jnp.int32(2), struck3)
            struck3 = jnp.where(healthy3, jnp.int32(-1), struck3)
            ev3 = (lvl == 3) & ~healthy3
            ev2 = (lvl == 2) & ~eq01
            events = (ev2 | ev3).astype(jnp.int32)
            struck = jnp.where(ev3, struck3, jnp.int32(-1))
        else:
            me = jax.lax.axis_index(pod_axis)
            m2 = (lvl == 2) & (me < 2)
            hm = jnp.where(m2[:, None], h, jnp.uint32(0))
            # psum over members 0,1 minus twice the local value: zero
            # words exactly where the two members agree (u32 wraparound)
            delta = psum_delta(hm, pod_axis)
            mism = m2 & jnp.any(delta != 0, axis=-1)
            events = jax.lax.psum((mism & (me == 0)).astype(jnp.int32), pod_axis)
            struck = jnp.full(lvl.shape, -1, jnp.int32)
        return events, struck

    mapped = shard_map(
        local,
        mesh=mesh,
        in_specs=(dec_specs, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(mapped)


def detect_wire_bytes(n_pods: int, spp: int, tmr: bool) -> int:
    """Per-pod per-tick cross-pod payload of one detect call (analytic;
    the bench reports it next to tokens/s).  DMR: the 16-byte-per-column
    fingerprint psum plus the 4-byte event-count psum.  TMR: the
    all_gather delivers every pod's (spp, 4) u32 block."""
    if tmr:
        return n_pods * spp * 16
    return spp * 16 + spp * 4


def pin_surgery(base: SlotSurgery, canon) -> SlotSurgery:
    """Wrap a surgery so every state-returning op lands back on the
    canonical shardings captured at ``engine.start()``.

    Host-side joins/copies otherwise come back with whatever sharding
    ``jit`` inferred, and feeding that into the shard_map'd step would
    either reshard on the wire every tick or recompile per layout.
    ``device_put`` onto an already-matching sharding is a no-copy no-op,
    so the temporal path could use this too — it just has nothing to pin.
    """

    def pin(st):
        return jax.device_put(st, canon)

    return dataclasses.replace(
        base,
        join=lambda *a, **k: pin(base.join(*a, **k)),
        scrub=lambda *a, **k: pin(base.scrub(*a, **k)),
        copy=lambda *a, **k: pin(base.copy(*a, **k)),
        adopt=lambda *a, **k: pin(base.adopt(*a, **k)),
    )

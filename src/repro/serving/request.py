"""Requests and the admission queue of the continuous batcher.

A ``Request`` is one decode job: a prompt, a token budget, an optional
deadline, and — the MISO twist — a per-request ``RedundancyPolicy``: the
*caller* chooses how dependable their own decode should be (none / DMR /
TMR), and pays for it in slots of the resident batch, without affecting
anyone else's latency or bytes.

``RequestQueue`` is the host-side admission layer: bounded depth
(back-pressure by rejection), FIFO ordering, lazy deadline expiry (a
request whose deadline passes while queued is never started), and
cancellation of queued work.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Callable, Optional

from repro.core.cell import NO_REDUNDANCY, RedundancyPolicy

# request lifecycle states
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"
EXPIRED = "expired"
REJECTED = "rejected"

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    """One decode request.

    prompt          -- model-specific payload (LM: (P,) int32 token array).
    max_new_tokens  -- decode budget (the prefill continuation counts as
                       token 1).
    policy          -- per-request dependability: level 1 = none, 2 = DMR
                       (detect + §IV third-execution tie-break), 3 = TMR
                       (detect + majority repair).  Costs ``level`` slots.
    deadline        -- absolute time (engine clock) after which the
                       request is dropped: while queued it expires
                       unstarted; while running it is evicted with
                       partial output.
    stop_token      -- optional early-stop token id.
    spec            -- optional speculative-decoding ask, interpreted by
                       the adapter (LM: ``models.lm_cells.SpecConfig`` —
                       its ``draft_len`` is the per-request draft
                       length, clamped to the engine's resident draft).
                       Output is bitwise-identical either way; spec only
                       changes how many tokens one tick can commit.
    """

    prompt: Any
    max_new_tokens: int = 16
    policy: RedundancyPolicy = NO_REDUNDANCY
    deadline: Optional[float] = None
    stop_token: Optional[int] = None
    spec: Any = None
    id: Optional[str] = None

    def __post_init__(self):
        if self.id is None:
            self.id = f"r{next(_ids)}"
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def n_slots(self) -> int:
        return self.policy.level

    @property
    def prompt_len(self) -> int:
        """Leading-axis length of the prompt payload (LM: token count).
        The paged-KV admission path sizes its worst-case page reservation
        from this plus ``max_new_tokens``."""
        return len(self.prompt)


class RequestQueue:
    """Bounded FIFO admission queue with deadlines and cancellation."""

    def __init__(
        self,
        max_depth: int = 64,
        time_fn: Callable[[], float] = time.monotonic,
        on_expire: Optional[Callable[[Request], None]] = None,
    ):
        self.max_depth = max_depth
        self.time_fn = time_fn
        self.on_expire = on_expire  # called per request dropped by expiry
        self._q: collections.deque[Request] = collections.deque()
        self.status: dict[str, str] = {}
        self.rejected = 0
        self.expired = 0
        self._deadlines = 0  # deadline-bearing entries currently queued

    @property
    def depth(self) -> int:
        return len(self._q)

    def submit(self, req: Request) -> bool:
        """Admit or reject (bounded queue = explicit back-pressure).

        The expiry sweep runs FIRST: dead entries anywhere in the deque
        must not hold ``depth`` against a fresh submission (a queue full
        of deadline-passed requests would otherwise reject live traffic
        — false back-pressure)."""
        self._expire()
        if len(self._q) >= self.max_depth:
            self.status[req.id] = REJECTED
            self.rejected += 1
            return False
        self.status[req.id] = QUEUED
        self._q.append(req)
        if req.deadline is not None:
            self._deadlines += 1
        return True

    def cancel(self, rid: str) -> bool:
        """Cancel a *queued* request (running ones are the engine's to
        evict).  True if it was found waiting.  Removal is by index —
        never by value: ``deque.remove`` would run the dataclass __eq__
        against every earlier entry, and ndarray prompts make that raise
        (ambiguous array truth value)."""
        for i, req in enumerate(self._q):
            if req.id == rid:
                del self._q[i]
                if req.deadline is not None:
                    self._deadlines -= 1
                self.status[rid] = CANCELLED
                return True
        return False

    def _expire(self) -> None:
        """Drop every deadline-passed request, wherever it sits in the
        deque.  (Head-only expiry left mid-queue corpses counted in
        ``depth``, causing false back-pressure rejections.)  O(1) when no
        queued request carries a deadline (the common case; peek runs
        every engine tick), one-pass partition rebuild otherwise — no
        value-based removal that would trip dataclass __eq__ on ndarray
        prompts."""
        if self._deadlines == 0:
            return
        now = self.time_fn()
        live: collections.deque[Request] = collections.deque()
        for r in self._q:
            if r.deadline is not None and r.deadline <= now:
                self.status[r.id] = EXPIRED
                self.expired += 1
                self._deadlines -= 1
                if self.on_expire is not None:
                    self.on_expire(r)
            else:
                live.append(r)
        self._q = live

    def peek(self) -> Optional[Request]:
        """Next admissible request (deadline-expired entries are dropped)."""
        self._expire()
        return self._q[0] if self._q else None

    def pop(self) -> Optional[Request]:
        self._expire()
        if not self._q:
            return None
        req = self._q.popleft()
        if req.deadline is not None:
            self._deadlines -= 1
        self.status[req.id] = RUNNING
        return req

    def take(self, req: Request) -> bool:
        """Pop a specific request the caller just ``peek``-validated —
        NO expiry re-sweep, so the head cannot change between the
        admission check and the pop (pop() re-runs expiry against a
        fresh clock reading: under deadline traffic it can return None
        or a request whose slot fit was never checked).  False if ``req``
        is no longer the head."""
        if self._q and self._q[0] is req:
            self._q.popleft()
            if req.deadline is not None:
                self._deadlines -= 1
            self.status[req.id] = RUNNING
            return True
        return False

#!/usr/bin/env python
"""Standalone checker for analyzer DAG exports (miso-analysis-dag/v1).

Validates ``python -m repro.analysis --dag-out`` JSON artifacts without
importing jax or the repo:

  python tools/validate_dag.py dags/*.json

Checks (the invariants the future taskgraph backend relies on — see
docs/analysis.md for the schema):

  * schema tag is ``miso-analysis-dag/v1`` and required keys exist;
  * every edge endpoint (leaf edges, refined/declared/dead reads) names
    a cell in ``cells``;
  * refined reads are a subset of declared reads, and disjoint from the
    dead reads (refined + dead = declared, per reader);
  * every refined edge is witnessed by at least one leaf edge;
  * the condensation partitions the cells exactly once, its edges index
    real SCCs, and it is topologically ordered producers-first;
  * metrics are consistent: n_cells, edge counts, and critical_path and
    width recomputed from the refined reads match the exported values.

Exit status 0 = all files valid; 1 = any violation (each printed).  The
CI ``analysis`` lane runs this over every exported program DAG.
"""

from __future__ import annotations

import json
import sys

REQUIRED_KEYS = (
    "schema",
    "program",
    "cells",
    "leaf_edges",
    "refined_reads",
    "declared_reads",
    "dead_reads",
    "condensation",
    "metrics",
)


def _sccs(names, reads):
    """Iterative Tarjan over the cell read graph (standalone mirror of
    core/graph.py, so the validator needs no repo imports)."""
    names = sorted(names)
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    out: list[frozenset] = []
    counter = [0]
    for root in names:
        if root in index:
            continue
        work = [(root, 0)]
        while work:
            node, pi = work[-1]
            if pi == 0:
                index[node] = low[node] = counter[0]
                counter[0] += 1
                stack.append(node)
                on_stack.add(node)
            recursed = False
            succs = [r for r in reads.get(node, []) if r != node]
            for i in range(pi, len(succs)):
                w = succs[i]
                if w not in index:
                    work[-1] = (node, i + 1)
                    work.append((w, 0))
                    recursed = True
                    break
                if w in on_stack:
                    low[node] = min(low[node], index[w])
            if recursed:
                continue
            if low[node] == index[node]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == node:
                        break
                out.append(frozenset(comp))
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
    return out


def _stages(names, reads):
    """Wavefront stages (cycles collapse via the SCC condensation),
    mirroring DependencyGraph.topo_stages without importing it."""
    comps = _sccs(names, reads)
    comp_of = {n: i for i, comp in enumerate(comps) for n in comp}
    depth: dict[int, int] = {}
    for i, comp in enumerate(comps):  # Tarjan emits reads-first
        preds = {comp_of[r] for n in comp for r in reads.get(n, []) if comp_of[r] != i}
        depth[i] = 1 + max((depth[j] for j in preds), default=-1)
    stages: dict[int, set] = {}
    for i, comp in enumerate(comps):
        stages.setdefault(depth[i], set()).update(comp)
    return [stages[d] for d in sorted(stages)]


def validate_doc(doc) -> list[str]:
    """Return a list of violation strings (empty = valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return [f"top level is {type(doc).__name__}, expected object"]
    for key in REQUIRED_KEYS:
        if key not in doc:
            errors.append(f"missing key {key!r}")
    if errors:
        return errors
    if doc["schema"] != "miso-analysis-dag/v1":
        errors.append(f"unknown schema {doc['schema']!r}")

    cells = {c.get("name") for c in doc["cells"]}
    if None in cells:
        errors.append("a cells[] entry has no name")
        cells.discard(None)

    for e in doc["leaf_edges"]:
        for end in ("reader", "cell"):
            if e.get(end) not in cells:
                errors.append(f"leaf edge {e} references unknown {end}")

    refined = doc["refined_reads"]
    declared = doc["declared_reads"]
    dead = doc["dead_reads"]
    for mapping, label in (
        (refined, "refined_reads"),
        (declared, "declared_reads"),
        (dead, "dead_reads"),
    ):
        for reader, reads in mapping.items():
            if reader not in cells:
                errors.append(f"{label} reader {reader!r} unknown")
            for r in reads:
                if r not in cells:
                    errors.append(f"{label}[{reader!r}] -> unknown {r!r}")

    witnessed = {(e["reader"], e["cell"]) for e in doc["leaf_edges"]}
    for reader in cells:
        ref = set(refined.get(reader, []))
        dec = set(declared.get(reader, []))
        dd = set(dead.get(reader, []))
        if not ref <= dec:
            errors.append(f"{reader!r}: refined reads exceed declared")
        if ref & dd:
            errors.append(f"{reader!r}: dead reads overlap refined")
        if ref | dd != dec:
            errors.append(f"{reader!r}: refined + dead != declared")
        for r in ref:
            if (reader, r) not in witnessed:
                errors.append(f"refined edge {reader!r}->{r!r} has no leaf witness")

    cond = doc["condensation"]
    seen: set = set()
    for comp in cond["sccs"]:
        for n in comp:
            if n in seen:
                errors.append(f"condensation repeats cell {n!r}")
            seen.add(n)
    if seen != cells:
        errors.append("condensation does not partition the cells")
    n_sccs = len(cond["sccs"])
    for i_str, js in cond["edges"].items():
        i = int(i_str)
        if not 0 <= i < n_sccs:
            errors.append(f"condensation edge source {i} out of range")
        for j in js:
            if not 0 <= j < n_sccs:
                errors.append(f"condensation edge target {j} out of range")
            elif j >= i:
                errors.append(f"condensation not producers-first: {i} reads {j}")

    m = doc["metrics"]
    if m["n_cells"] != len(cells):
        errors.append(f"metrics.n_cells {m['n_cells']} != {len(cells)}")
    if m["n_leaf_edges"] != len(doc["leaf_edges"]):
        errors.append("metrics.n_leaf_edges mismatch")
    n_cell_edges = sum(len(r) for r in refined.values())
    if m["n_cell_edges"] != n_cell_edges:
        errors.append("metrics.n_cell_edges mismatch")
    stages = _stages(cells, refined)
    depth = len(stages)
    width = max((len(s) for s in stages), default=0)
    if cells and m["critical_path"] != depth:
        errors.append(
            f"metrics.critical_path {m['critical_path']} != recomputed "
            f"{depth}"
        )
    if cells and m["width"] != width:
        errors.append(f"metrics.width {m['width']} != recomputed {width}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: validate_dag.py dag.json [more.json ...]")
        return 2
    bad = False
    for path in argv:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}")
            bad = True
            continue
        errors = validate_doc(doc)
        for err in errors:
            print(f"{path}: {err}")
        if errors:
            bad = True
        else:
            m = doc.get("metrics", {})
            print(
                f"{path}: ok ({m.get('n_cells')} cells, "
                f"critical path {m.get('critical_path')}, "
                f"width {m.get('width')})"
            )
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python
"""Standalone Chrome trace-event JSON schema checker.

Validates any exported trace (``Tracer.export`` output, or anything in
the trace-event format) without importing the repo:

  python tools/validate_trace.py trace.json [more.json ...]

Checks (the invariants Perfetto's importer relies on, and the ones our
exporter promises — see docs/observability.md):

  * top level is a list of events or a dict with a ``traceEvents`` list;
  * every event has ``ph``, ``pid``, ``tid``, and a numeric ``ts``
    (metadata ``M`` events may omit ``ts``), with a known phase;
  * ``X`` complete events carry a numeric ``dur`` >= 0;
  * ``B``/``E`` duration events balance as a stack per (pid, tid);
  * ``s``/``f`` flow events carry ids, and every flow id has both ends.

Exit status 0 = valid; 1 = any violation (each printed).  CI runs this
against the serving smoke's ``--trace-out`` artifact, and
tests/test_obs.py imports ``validate_events`` to gate the exporter.
"""

from __future__ import annotations

import json
import sys

KNOWN_PHASES = set("BEXisfMC")


def validate_events(events) -> list[str]:
    """Return a list of violation strings (empty = valid)."""
    errors: list[str] = []
    if not isinstance(events, list):
        return [f"traceEvents is {type(events).__name__}, expected list"]
    open_spans: dict[tuple, list[str]] = {}
    flow_starts: dict = {}
    flow_ends: dict = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph is None:
            errors.append(f"event {i}: missing ph")
            continue
        if ph not in KNOWN_PHASES:
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        for field in ("pid", "tid"):
            if field not in e:
                errors.append(f"event {i} (ph={ph}): missing {field}")
        ts = e.get("ts")
        if ts is None:
            if ph != "M":  # metadata may omit the timestamp
                errors.append(f"event {i} (ph={ph}): missing ts")
        elif not isinstance(ts, (int, float)):
            errors.append(f"event {i} (ph={ph}): non-numeric ts {ts!r}")
        key = (e.get("pid"), e.get("tid"))
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event {i} (X {e.get('name')!r}): "
                    f"dur must be a number >= 0, got {dur!r}"
                )
        elif ph == "B":
            open_spans.setdefault(key, []).append(str(e.get("name")))
        elif ph == "E":
            stack = open_spans.get(key)
            if not stack:
                errors.append(f"event {i}: E with no open B on pid/tid {key}")
            else:
                stack.pop()
        elif ph in ("s", "f"):
            if "id" not in e:
                errors.append(f"event {i} (ph={ph}): flow without id")
            else:
                side = flow_starts if ph == "s" else flow_ends
                side.setdefault(e["id"], []).append(i)
    for key, stack in open_spans.items():
        if stack:
            errors.append(
                f"pid/tid {key}: {len(stack)} unclosed B span(s) "
                f"({', '.join(stack[:4])})"
            )
    for fid in flow_starts:
        if fid not in flow_ends:
            errors.append(f"flow id {fid!r}: start (s) without finish (f)")
    for fid in flow_ends:
        if fid not in flow_starts:
            errors.append(f"flow id {fid!r}: finish (f) without start (s)")
    return errors


def validate_file(path: str) -> list[str]:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: cannot load JSON: {e}"]
    if isinstance(doc, dict):
        if "traceEvents" not in doc:
            return [f"{path}: dict without a traceEvents key"]
        events = doc["traceEvents"]
    else:
        events = doc
    return [f"{path}: {err}" for err in validate_events(events)]


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    failed = False
    for path in argv:
        errors = validate_file(path)
        if errors:
            failed = True
            for err in errors:
                print(f"FAIL {err}")
        else:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
            n = len(doc["traceEvents"] if isinstance(doc, dict) else doc)
            print(f"ok   {path}: {n} events, schema valid")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Check relative links in the repo's markdown docs.

Scans README.md, docs/*.md, ROADMAP.md, CHANGES.md, PAPER.md for
markdown links ``[text](target)`` and fails (exit 1) when a RELATIVE
target does not resolve to a file or directory in the repo.  External
links (http/https/mailto) and pure in-page anchors (#...) are skipped;
a relative target's ``#fragment`` suffix is stripped before the check
(fragments are not validated).  Inline code spans and fenced code
blocks are ignored, so example snippets can show link syntax freely.

The CI docs gate runs this on every PR:

    python tools/check_links.py            # from the repo root
    python tools/check_links.py docs README.md   # explicit targets
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^(```|~~~)")
CODE_SPAN = re.compile(r"`[^`]*`")

DEFAULT_TARGETS = ("README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md",
                   "PAPERS.md", "docs")


def md_files(root: Path, targets: tuple[str, ...]) -> list[Path]:
    out = []
    for t in targets:
        p = root / t
        if p.is_dir():
            out.extend(sorted(p.glob("**/*.md")))
        elif p.is_file():
            out.append(p)
    return out


def check_file(path: Path, root: Path) -> list[str]:
    errors = []
    in_fence = False
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), start=1):
        if FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK.finditer(CODE_SPAN.sub("``", line)):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            try:
                resolved.relative_to(root.resolve())
            except ValueError:
                errors.append(
                    f"{path.relative_to(root)}:{lineno}: link "
                    f"escapes the repo: {target}"
                )
                continue
            if not resolved.exists():
                errors.append(
                    f"{path.relative_to(root)}:{lineno}: broken "
                    f"relative link: {target}"
                )
    return errors


def main(argv: list[str]) -> int:
    root = Path(__file__).resolve().parent.parent
    targets = tuple(argv) if argv else DEFAULT_TARGETS
    files = md_files(root, targets)
    if not files:
        print(f"check_links: no markdown files under {targets}", file=sys.stderr)
        return 1
    errors = []
    for f in files:
        errors.extend(check_file(f, root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
